"""Compiled executor: equivalence with the interpreter + backend plumbing.

The compiled backend (``exec_compiled``) must reproduce the interpreter's
latencies and per-rank clocks to ~1e-9 relative across every schedule,
transport (eager/rendez-vous), contention regime and rank placement — the
interpreter stays the reference semantics.  The hypothesis twin of the
deterministic fuzz here lives in ``test_property.py``.
"""

import random

import numpy as np
import pytest

from repro.core.exanet import ExanetMPI
from repro.core.exanet.exec_compiled import ProgramStructureError
from repro.core.exanet.params import DEFAULT, scaled_params
from repro.core.exanet.schedules import (AllGather, AllToAll, Barrier,
                                         BinomialBroadcast, GatherBinomial,
                                         HierarchicalAccelAllreduce,
                                         OneShotAllreduce,
                                         RabenseifnerAllreduce,
                                         RecursiveDoublingAllreduce,
                                         RingAllreduce, Round,
                                         ScatterBinomial, Schedule)

SCHEDULES = (BinomialBroadcast, RecursiveDoublingAllreduce, RingAllreduce,
             RabenseifnerAllreduce, OneShotAllreduce, AllGather, AllToAll,
             Barrier, ScatterBinomial, GatherBinomial,
             HierarchicalAccelAllreduce)
#: straddles mpi_eager_max_bytes (32) and the 16 KB RDMA block size
SIZES = (1, 31, 32, 33, 4096, 1 << 20)


def _assert_equal(a, b, tag, rel=1e-9):
    assert b.latency_us == pytest.approx(a.latency_us, rel=rel), tag
    assert a.round_heads == b.round_heads, tag
    for x, y in zip(a.clocks, b.clocks):
        assert y == pytest.approx(x, rel=rel, abs=1e-12), tag


@pytest.fixture(scope="module", params=[None, 1],
                ids=["rpm4", "rpm1"])
def mpi(request):
    return ExanetMPI(ranks_per_mpsoc=request.param)


@pytest.mark.parametrize("sched_cls", SCHEDULES,
                         ids=[c.__name__ for c in SCHEDULES])
def test_compiled_matches_interpreter(mpi, sched_cls):
    sched = sched_cls()
    for nranks in (4, 16):
        for size in SIZES:
            try:
                a = mpi.run_schedule(sched, size, nranks, backend="interp")
            except (ValueError, AssertionError):
                continue  # shape infeasible for this schedule
            b = mpi.run_schedule(sched, size, nranks, backend="compiled")
            _assert_equal(a, b, (sched.name, nranks, size))


def test_run_schedule_many_matches_per_size(mpi):
    sched = RecursiveDoublingAllreduce()
    batch = mpi.run_schedule_many(sched, SIZES, 16)
    assert batch.latency_us.shape == (len(SIZES),)
    assert batch.clocks.shape == (len(SIZES), 16)
    for i, size in enumerate(SIZES):
        ref = mpi.run_schedule(sched, size, 16, backend="interp")
        assert float(batch.latency_us[i]) == \
            pytest.approx(ref.latency_us, rel=1e-9)
        np.testing.assert_allclose(batch.clocks[i], ref.clocks, rtol=1e-9)


# ------------------------------------------------------------- satellites
class _FixedSchedule(Schedule):
    """A literal round list (test-only)."""
    name = "fixed"

    def __init__(self, rounds, one_way=False):
        self._rounds = tuple(rounds)
        self.one_way = one_way

    def rounds(self, nranks, nbytes):
        return iter(self._rounds)


def test_duplicate_sender_waits_for_both_sends(mpi):
    """Regression (PR 3 satellite): a rank sending twice in one exchange
    round must wait for BOTH sends — ``done[s]`` is a max, not
    last-write-wins.  The slow rendez-vous send used to be overwritten by
    the fast eager one."""
    slow, fast = 1 << 20, 1
    sched = _FixedSchedule([Round(0, ((0, 1, slow), (0, 2, fast)),
                                  exchange=True)])
    res = mpi.run_schedule(sched, 0, 3, backend="interp")
    # rank 0's clock includes the rendez-vous completion (~hundreds of us
    # of stream time), not just the eager packetizer return (~0.25 us)
    only_slow = _FixedSchedule([Round(0, ((0, 1, slow),), exchange=True)])
    floor = mpi.run_schedule(only_slow, 0, 3, backend="interp").clocks[0]
    assert res.clocks[0] >= floor - 1e-9
    _assert_equal(res, mpi.run_schedule(sched, 0, 3, backend="compiled"),
                  "duplicate-sender")


def test_r5_list_cached_per_rank_count(mpi):
    """Satellite: the per-rank R5 resource list is hoisted into a
    per-nranks cache instead of being rebuilt every collective."""
    r5s_a = mpi._r5s(8)
    r5s_b = mpi._r5s(8)
    assert r5s_a is r5s_b
    assert mpi._r5s(4) is not r5s_a
    # same engine Resource objects the interpreter serializes on
    assert all(r is mpi.net.engine.resource("r5",
                                            mpi.topo.core_to_mpsoc(c))
               for r, c in zip(r5s_a, mpi._cores(8)))


def test_seeded_fuzz_compiled_equals_interp():
    """Deterministic fuzz across random round structures: duplicate and
    self sends, mixed per-send transports, exchange/one-way, reductions,
    sync skew, both placements (the hypothesis twin is in
    test_property.py)."""
    BYTES = [0, 1, 31, 32, 33, 100, 4096, 65536, 300000]
    mpis = {rpm: ExanetMPI(ranks_per_mpsoc=rpm) for rpm in (None, 1)}
    for seed in range(60):
        rng = random.Random(seed)
        rpm = rng.choice([None, 1])
        n = rng.choice([2, 4, 8, 16])
        rounds = []
        for step in range(rng.randint(1, 4)):
            uniform = rng.random() < 0.5
            nb0 = rng.choice(BYTES)
            sends = tuple((rng.randrange(n), rng.randrange(n),
                           nb0 if uniform else rng.choice(BYTES))
                          for _ in range(rng.randint(1, 12)))
            rounds.append(Round(step, sends, exchange=rng.random() < 0.5,
                                reduce_bytes=rng.choice([0, 64, 4096]),
                                sync=rng.random() < 0.3))
        sched = _FixedSchedule(rounds, one_way=rng.random() < 0.5)
        mpi = mpis[rpm]
        a = mpi.run_schedule(sched, 0, n, backend="interp")
        b = mpi.run_schedule(sched, 0, n, backend="compiled")
        _assert_equal(a, b, ("fuzz", seed))


# ----------------------------------------------------- backend selection
def test_program_and_bind_caching(mpi):
    sched = RecursiveDoublingAllreduce()
    prog = mpi.compiled_program(sched, 8)
    assert mpi.compiled_program(RecursiveDoublingAllreduce(), 8) is prog
    b1 = prog.bind(sched, SIZES)
    assert prog.bind(sched, SIZES) is b1


def test_compiled_rejects_tracing_engine():
    mpi = ExanetMPI(trace=True)
    with pytest.raises(ValueError, match="trace"):
        mpi.run_schedule_many(RecursiveDoublingAllreduce(), (64,), 8)
    # auto silently stays on the interpreter (and records the trace)
    res = mpi.run_schedule(RecursiveDoublingAllreduce(), 64, 8)
    assert res.latency_us > 0 and len(mpi.net.trace) > 0


class _SizeVaryingSchedule(Schedule):
    """Round structure depends on the payload size (pathological)."""
    name = "size_varying"

    def rounds(self, nranks, nbytes):
        d = 1 + (nbytes > 64)  # different pairs at different sizes
        yield Round(0, tuple((r, (r + d) % nranks, nbytes)
                             for r in range(nranks)), exchange=True)


def test_size_varying_structure_rejected_and_auto_falls_back(monkeypatch):
    mpi = ExanetMPI()
    sched = _SizeVaryingSchedule()
    with pytest.raises(ProgramStructureError):
        mpi.run_schedule_many(sched, (1, 4096), 8)
    # backend="auto" falls back to the interpreter instead of failing
    monkeypatch.setattr(ExanetMPI, "COMPILED_AUTO_MIN_RANKS", 2)
    monkeypatch.setattr(ExanetMPI, "COMPILED_MIN_PARALLELISM", 0.0)
    a = mpi.run_schedule(sched, 1, 8, backend="interp")
    b = mpi.run_schedule(sched, 1, 8, backend="auto")
    _assert_equal(a, b, "auto-fallback")


def test_parallelism_predictor_separates_ring_from_wide():
    """The ring's r -> r+1 pattern serial-chains every DMA engine; wide
    XOR rounds vectorize.  The predictor is what keeps ``auto`` and the
    planner's cost_many off the compiled path for chain schedules."""
    mpi = ExanetMPI(ranks_per_mpsoc=1)
    assert not mpi.compiled_profitable(RingAllreduce(), 64)
    assert mpi.compiled_profitable(RecursiveDoublingAllreduce(), 64)
    assert mpi.compiled_profitable(BinomialBroadcast(), 64)


# ------------------------------------------------------- paper-scale runs
def test_scaled_params_grow_torus():
    p = scaled_params(4096)
    assert p.n_cores >= 4096
    assert p.mezz_torus_y * p.mezz_torus_z == p.mezzanines
    # calibrated constants untouched
    assert p.rdma_startup_us == DEFAULT.rdma_startup_us
    assert p.rate_mezz_gbps == DEFAULT.rate_mezz_gbps
    assert scaled_params(100) is DEFAULT


def test_route_bounds_checked():
    from repro.core.exanet import Topology
    with pytest.raises(ValueError, match="scaled_params"):
        Topology().route(0, DEFAULT.n_cores)


def test_paper_scale_1024_ranks_compiled_matches_interp():
    """1024 ranks (1/MPSoC) on a scaled torus — the sweep scale that was
    impractical before the compiled backend."""
    mpi = ExanetMPI(scaled_params(4096), ranks_per_mpsoc=1)
    sched = BinomialBroadcast()
    a = mpi.run_schedule(sched, 4096, 1024, backend="interp")
    b = mpi.run_schedule(sched, 4096, 1024, backend="compiled")
    _assert_equal(a, b, "1024-rank bcast")
    # at this scale "auto" picks the compiled backend on wide schedules
    assert mpi.compiled_profitable(sched, 1024)


# ------------------------------------------------------- batched planning
def test_plan_many_matches_plan_and_fills_cache():
    from repro.core.machine import ExanetMachine
    from repro.core.planner import CollectivePlanner
    sizes = [1, 256, 4096, 1 << 16, 1 << 20]
    a_pl = CollectivePlanner(ExanetMachine(), fidelity="sim")
    plans = a_pl.plan_many("allreduce", sizes, (16,))
    b_pl = CollectivePlanner(ExanetMachine(), fidelity="sim")
    for plan, size in zip(plans, sizes):
        ref = b_pl.plan("allreduce", size, (16,))
        assert plan.schedule == ref.schedule
        assert plan.cost_s == pytest.approx(ref.cost_s, rel=1e-9)
        for (n1, c1), (n2, c2) in zip(plan.costs, ref.costs):
            assert n1 == n2 and c1 == pytest.approx(c2, rel=1e-9)
    # batched results landed in the same memoization the scalar path uses
    hits0 = a_pl.cache_info()["hits"]
    again = a_pl.plan_many("allreduce", sizes, (16,))
    assert [p.schedule for p in again] == [p.schedule for p in plans]
    assert a_pl.cache_info()["hits"] >= hits0 + len(sizes)


def test_machine_tiers_answer_beyond_prototype_capacity():
    """256 ranks at 1/MPSoC need 1024 cores — more than the prototype's
    512.  The machine scales a twin torus per tier instead of failing."""
    from repro.core.machine import ExanetMachine
    m = ExanetMachine()
    c = m.cost_s(RecursiveDoublingAllreduce(), 256, 4096, fidelity="sim")
    assert c > 0
    assert m._mpi_for(256) is m._mpi_for(256)      # one instance per tier
    assert m._mpi_for(16) is m.mpi                 # small queries unscaled
