"""Program IR execution semantics (DESIGN.md §2.6).

Covers the tentpole contract: nonblocking point-to-point with tag
matching, compute/communication overlap up to the critical path, deadlock
detection, agreement with the closed-form model for isolated transfers,
one-pass collective planning, machine-level program costing, and the
apps-on-programs regression for custom HwParams.
"""

import dataclasses

import pytest

from repro.core.exanet.mpi import ExanetMPI
from repro.core.exanet.params import DEFAULT
from repro.core.machine import ExanetMachine, TpuMachine
from repro.core.planner import CollectivePlanner
from repro.core.program import (Collective, Compute, Irecv, Isend, Program,
                                ProgramDeadlockError, ProgramError, Wait,
                                analytic_program_us, balanced_grid3,
                                bsp_step, cg_iteration, halo3d)


@pytest.fixture(scope="module")
def mpi():
    return ExanetMPI()


@pytest.fixture(scope="module")
def mpi1():  # one rank per MPSoC: pairs cross a real link
    return ExanetMPI(ranks_per_mpsoc=1)


# ----------------------------------------------------------------- builders
def test_halo3d_structure_and_counts(mpi):
    prog = halo3d(8, 1024, 10.0)          # 2x2x2 grid: all 6 faces real
    assert prog.nranks == 8
    c = prog.counts()
    assert c["isend"] == c["irecv"] == 8 * 6
    res = mpi.run_program(prog)
    assert res.n_sends == 8 * 6           # every face matched exactly once
    assert res.latency_us > 10.0
    assert res.compute_us == (10.0,) * 8


def test_balanced_grid3():
    assert sorted(balanced_grid3(8)) == [2, 2, 2]
    assert sorted(balanced_grid3(512)) == [8, 8, 8]
    px, py, pz = balanced_grid3(2)
    assert px * py * pz == 2


def test_two_rank_periodic_grid_needs_tags(mpi):
    # 1x1x2 grid: both z faces go to the same neighbour; only the tag
    # distinguishes them — program must still match cleanly
    prog = halo3d(2, 4096, 0.0)
    res = mpi.run_program(prog)
    assert res.n_sends == 4               # 2 faces x 2 ranks


# ------------------------------------------------------------------ overlap
def test_compute_hides_communication_up_to_critical_path(mpi):
    face, comp = 8192, 400.0
    t_comm = mpi.run_program(halo3d(2, face, 0.0)).latency_us
    t_overlap = mpi.run_program(halo3d(2, face, comp,
                                       overlap=True)).latency_us
    t_serial = mpi.run_program(halo3d(2, face, comp)).latency_us
    # serial = comm then compute; overlapped compute swallows the comm
    assert t_serial == pytest.approx(t_comm + comp, rel=1e-9)
    assert t_overlap < t_serial - 0.9 * min(comp, t_comm)
    assert t_overlap >= comp              # critical path floor


def test_overlap_floor_is_communication_when_compute_small(mpi):
    face = 8192
    t_comm = mpi.run_program(halo3d(2, face, 0.0)).latency_us
    t_overlap = mpi.run_program(halo3d(2, face, 1.0,
                                       overlap=True)).latency_us
    assert t_overlap == pytest.approx(t_comm, rel=0.05)


# ------------------------------------------------------------- tag matching
def test_tags_match_out_of_order_posts(mpi):
    # rank 0 sends tag 0 (100 B) then tag 1 (5000 B); rank 1 posts the
    # receives in *reverse* tag order.  Only tag-based matching pairs the
    # sizes correctly (FIFO-by-arrival would raise a size mismatch).
    prog = Program((
        (Isend(1, 100, tag=0), Isend(1, 5000, tag=1), Wait()),
        (Irecv(0, 5000, tag=1), Irecv(0, 100, tag=0), Wait()),
    ))
    res = mpi.run_program(prog)
    assert res.n_sends == 2


def test_size_mismatch_on_matched_channel_raises(mpi):
    prog = Program((
        (Isend(1, 100, tag=0), Wait()),
        (Irecv(0, 200, tag=0), Wait()),
    ))
    with pytest.raises(ProgramError, match="size mismatch"):
        mpi.run_program(prog)


def test_named_handles_selective_wait(mpi):
    prog = Program((
        (Isend(1, 64, tag=0, handle="a"), Isend(1, 64, tag=1, handle="b"),
         Wait(("a",)), Compute(5.0), Wait(("b",))),
        (Irecv(0, 64, tag=0), Irecv(0, 64, tag=1), Wait()),
    ))
    res = mpi.run_program(prog)
    assert res.n_sends == 2


# ------------------------------------------------------ deadlock detection
def test_deadlock_on_mismatched_tags(mpi):
    prog = Program((
        (Irecv(1, 100, tag=7), Wait()),
        (Isend(0, 100, tag=8), Wait()),
    ))
    with pytest.raises(ProgramDeadlockError, match="unmatched"):
        mpi.run_program(prog)


def test_deadlock_on_missing_collective_participant(mpi):
    prog = Program((
        (Collective("allreduce", 64, "recursive_doubling"),),
        (Compute(1.0),),
    ))
    with pytest.raises(ProgramDeadlockError, match="collective barrier"):
        mpi.run_program(prog)


def test_collective_signature_mismatch_raises(mpi):
    # ranks must reach *matching* collectives in the same order; merging
    # a barrier with an allreduce would silently cost the wrong thing
    prog = Program((
        (Collective("allreduce", 1024, "recursive_doubling"),),
        (Collective("barrier", 0, "dissemination"),),
    ))
    with pytest.raises(ProgramError, match="collective mismatch"):
        mpi.run_program(prog)


def test_unmatched_request_at_exit_raises(mpi):
    prog = Program((
        (Isend(1, 8, tag=0),),   # eager, never received, never waited
        (Compute(1.0),),
    ))
    with pytest.raises(ProgramError, match="unmatched"):
        mpi.run_program(prog)


def test_validate_rejects_out_of_range_peer():
    with pytest.raises(ProgramError, match="outside"):
        Program(((Isend(3, 8),),)).validate()


# ------------------------------------------- closed-form / interp agreement
def test_single_isolated_transfer_matches_closed_form(mpi1):
    """One rendez-vous Isend/Irecv pair with no contention must reproduce
    the closed-form one-way latency (osu_one_way) the retired apps model
    was built from."""
    size = 8000
    prog = Program((
        (Isend(1, size, tag=0), Wait()),
        (Irecv(0, size, tag=0), Wait()),
    ))
    res = mpi1.run_program(prog)
    expected = mpi1.osu_one_way(size, 0, 1)
    assert res.latency_us == pytest.approx(expected, rel=0.02)


def test_single_eager_transfer_matches_closed_form(mpi1):
    size = 16   # <= 32 B: eager transport
    prog = Program((
        (Isend(1, size, tag=0), Wait()),
        (Irecv(0, size, tag=0), Wait()),
    ))
    res = mpi1.run_program(prog)
    expected = mpi1.osu_one_way(size, 0, 1)
    assert res.latency_us == pytest.approx(expected, rel=0.05)


def test_sim_matches_analytic_walker_without_contention(mpi1):
    """The event engine and the alpha-beta walker agree on a one-direction
    transfer chain when there is nothing to contend on."""
    size = 65536
    prog = Program((
        (Compute(10.0), Isend(1, size, tag=0), Wait()),
        (Irecv(0, size, tag=0), Wait(), Compute(10.0)),
    ))
    sim = mpi1.run_program(prog).latency_us
    m = mpi1.net.path_metrics(0, mpi1.rank_core(1))
    alpha = m.handshake_ow_us + DEFAULT.rdma_startup_us + m.hop_latency_us
    bw = m.rdma_bw_gbps * 1000.0 / 8.0   # bytes/us
    ana = analytic_program_us(prog, alpha_us=alpha, bw_bytes_per_us=bw,
                              coll_cost_us=lambda *a: 0.0).latency_us
    assert sim == pytest.approx(ana, rel=0.03)


def test_embedded_collective_matches_standalone(mpi):
    """A program that is just one collective costs the standalone
    run_schedule latency (same engine, zero-occupancy entry)."""
    prog = bsp_step(8, 0.0, "allreduce", 4096,
                    coll_algo="recursive_doubling")
    res = mpi.run_program(prog)
    direct = mpi.allreduce(4096, 8, "recursive_doubling")
    assert res.latency_us == pytest.approx(direct, rel=1e-12)


def test_run_schedule_t0_is_time_shift_invariant(mpi):
    from repro.core.exanet.schedules import RecursiveDoublingAllreduce
    base = mpi.run_schedule(RecursiveDoublingAllreduce(), 1024, 8,
                            backend="interp")
    shifted = mpi.run_schedule(RecursiveDoublingAllreduce(), 1024, 8,
                               backend="interp", t0=[100.0] * 8)
    assert shifted.latency_us == pytest.approx(base.latency_us + 100.0,
                                               rel=1e-12)
    # a skewed fresh start is exact on the compiled backend too; only
    # reset=False (nonzero live occupancy) stays interpreter-only
    compiled = mpi.run_schedule(RecursiveDoublingAllreduce(), 1024, 8,
                                backend="compiled", t0=[100.0] * 8)
    assert compiled.latency_us == pytest.approx(shifted.latency_us,
                                                rel=1e-9)
    with pytest.raises(ValueError, match="compiled"):
        mpi.run_schedule(RecursiveDoublingAllreduce(), 1024, 8,
                         backend="compiled", t0=[0.0] * 8, reset=False)


# ------------------------------------------------- congestion is emergent
def test_concurrent_halo_flows_contend(mpi1):
    """8 ranks exchanging simultaneously must be slower than the isolated
    closed-form sum of one rank's faces — this gap is what the retired
    alpha had to fake."""
    face = 32768
    prog = halo3d(8, face, 0.0)
    sim = mpi1.run_program(prog).latency_us
    isolated = 3 * mpi1.osu_one_way(face, 0, 1)   # 3 face-pairs, overlap
    assert sim > 1.5 * isolated


# -------------------------------------------------------- planner / machine
def test_plan_program_plans_every_auto_site_in_one_pass():
    planner = CollectivePlanner(ExanetMachine(), fidelity="analytic")
    prog = Program(tuple(
        (Collective("allreduce", 256), Compute(1.0),
         Collective("allreduce", 1 << 20),
         Collective("allreduce", 256),          # duplicate site: one plan
         Collective("barrier", 0))              # non-allreduce: no plan
        for _ in range(8)))
    plans = planner.plan_program(prog)
    assert set(plans) == {("allreduce", 256), ("allreduce", 1 << 20)}
    info = planner.cache_info()
    # replanning is pure cache hits
    planner.plan_program(prog)
    assert planner.cache_info()["misses"] == info["misses"]


def test_cost_program_fidelities(mpi):
    machine = ExanetMachine(mpi=mpi)
    prog = cg_iteration(8, 4096, 200.0, coll_algo="recursive_doubling")
    sim = machine.cost_program(prog, fidelity="sim")
    ana = machine.cost_program(prog, fidelity="analytic")
    assert sim > 200e-6 and ana > 200e-6     # both include the compute
    compute_only = bsp_step(8, 300.0)
    assert machine.cost_program(compute_only, fidelity="sim") == \
        pytest.approx(300e-6, rel=1e-9)
    assert machine.cost_program(compute_only, fidelity="analytic") == \
        pytest.approx(300e-6, rel=1e-9)


def test_accel_collective_costs_at_both_fidelities(mpi):
    machine = ExanetMachine(mpi=mpi)
    prog = bsp_step(8, 0.0, "allreduce", 4096, coll_algo="accel")
    sim = machine.cost_program(prog, fidelity="sim")
    ana = machine.cost_program(prog, fidelity="analytic")
    # the §4.7 engine is a closed form on either path: identical numbers
    assert sim == pytest.approx(ana, rel=1e-12)
    with pytest.raises(ValueError, match="accelerator"):
        TpuMachine().cost_program(prog)
    # analytic auto considers the accelerator too (the planner's twin):
    # at 256 B the accel closed form beats every software alpha-beta cost
    from repro.core.exanet.allreduce_accel import accel_cost_us
    auto = machine.cost_program(bsp_step(64, 0.0, "allreduce", 256),
                                fidelity="analytic")
    assert auto <= accel_cost_us(256, 64, machine.params) * 1e-6 + 1e-12


def test_tpu_machine_costs_programs():
    tpu = TpuMachine()
    prog = bsp_step(16, 50.0, "allreduce", 1 << 20)
    cost = tpu.cost_program(prog)
    assert cost > 50e-6
    # auto picks the cheapest feasible schedule: never worse than ring
    from repro.core.exanet.schedules import RingAllreduce
    ring = tpu.cost_s(RingAllreduce(), 16, 1 << 20)
    assert cost <= 50e-6 + ring + 1e-12


def test_grad_sync_program_emission(mpi):
    from repro.parallel.grad_sync import emit_sync_program
    sizes = [4 << 20, 64 << 10, 256]
    prog = emit_sync_program(4, sizes, compute_us_per_bucket=100.0)
    assert prog.nranks == 4
    assert [c.nbytes for c in prog.collectives()] == sizes
    res = mpi.run_program(prog)   # algo="auto": planned per bucket
    assert res.latency_us >= 300.0
    assert res.n_collectives == 3
    with pytest.raises(ValueError, match="buckets"):
        emit_sync_program(4, sizes, compute_us_per_bucket=[1.0])


# ------------------------------------------------------- apps on programs
def test_apps_emit_programs_and_params_matter():
    """Regression for the dropped-params bug: factories must hand their
    HwParams to the model, and a machine with different hardware must
    produce different simulated iterations."""
    from repro.core.exanet.apps import hpcg
    slow = dataclasses.replace(
        DEFAULT, bw_wire_intra_qfdb_gbps=6.5, bw_wire_mezz_gbps=3.2,
        rate_intra_qfdb_gbps=8.0, rate_mezz_gbps=5.0)
    m_def, m_slow = hpcg(), hpcg(slow)
    assert m_def.params is DEFAULT
    assert m_slow.params is slow           # the PR-4 satellite fix
    comm_def = m_def._simulate("weak", 8).comm_us
    comm_slow = m_slow._simulate("weak", 8).comm_us
    assert comm_slow > 1.3 * comm_def      # halved links, slower halos


def test_apps_reuse_one_mpi_instance():
    from repro.core.exanet.apps import minife
    m = minife()
    assert m.mpi is m.mpi                  # built once, not per eval
    m._simulate("weak", 2)
    m._simulate("strong", 2)
    assert m._mpi is m.mpi


def test_app_iteration_programs_have_halo_and_dots():
    from repro.core.exanet.apps import hpcg
    prog = hpcg().emit_iteration("weak", 8)
    c = prog.counts()
    assert c["isend"] == 8 * 6
    assert c["collective"] == 8 * 2        # 2 dot allreduces per rank
    assert all(col.algo == "recursive_doubling"   # MPICH 3.2.1, §5.2.1
               for col in prog.collectives())
