"""Batch-binding axis + scan-engine seam (DESIGN.md §2.5, PR 6).

Three contracts:

* **Engines** — ``engine="numpy"`` and ``engine="jax"`` are
  interchangeable scan backends; resolution errors are clear, and a
  missing jax degrades gracefully (the numpy default keeps working, the
  jax request names requirements-dev.txt).  jax-lane tests
  ``importorskip`` the dependency, mirroring the hypothesis pattern.
* **Batched == per-binding** to ≤1e-9 for both engines: message-size
  grids and arrival-offset ``t0`` columns through
  ``run_schedule_many``, fuzzed Program batches (mixed structures,
  compute skew, tag permutations, eager/rendez-vous payloads) through
  ``run_program_many``/``bind_batch``, and array-bound Monte-Carlo
  scenario columns through ``run_program_scenarios``/``bind_arrays``.
  The hypothesis twin lives in ``test_property.py``.
* **Auto gate** — ``backend="auto"`` never picks a losing executor:
  below the rank floor programs stay interpreted (the BENCH_apps 0.87x
  nranks=2 regression), at scale the consolidated gate compiles.
"""

import random
import sys

import numpy as np
import pytest

from repro.core.exanet import ExanetMPI
from repro.core.exanet import scan_engine as se
from repro.core.exanet.program_compiled import (extract_data,
                                                rebind_program)
from repro.core.exanet.schedules import (RabenseifnerAllreduce,
                                         RecursiveDoublingAllreduce)
from test_program_compiled import BYTES, _assert_equal, _fuzz_program

MPI = ExanetMPI()


@pytest.fixture(params=["numpy", "jax"])
def engine(request):
    if request.param == "jax":
        pytest.importorskip("jax")
    return request.param


# ----------------------------------------------------- engine resolution
def test_unknown_engine_name_lists_options():
    with pytest.raises(ValueError, match=r"unknown scan engine 'torch'"):
        se.get_scan_engine("torch")
    with pytest.raises(ValueError, match=r"\['jax', 'numpy'\]"):
        se.get_scan_engine("cupy")


def test_resolve_engine_normalization():
    assert se.resolve_engine(None) is se.NUMPY
    assert se.resolve_engine("numpy") is se.NUMPY
    assert se.resolve_engine(se.NUMPY) is se.NUMPY  # object passthrough
    with pytest.raises(ValueError, match="not a scan engine"):
        se.resolve_engine(3)


def test_missing_jax_degrades_gracefully(monkeypatch):
    """Without the optional dependency the numpy default still works and
    the jax request raises a clear install hint (satellite: graceful
    degradation; simulated by blocking the import)."""
    monkeypatch.setattr(se, "_jax", None)
    monkeypatch.delitem(se._engines, "jax", raising=False)
    monkeypatch.setitem(sys.modules, "jax", None)  # import jax -> ImportError
    assert se.available_engines() == ["numpy"]
    with pytest.raises(RuntimeError, match="requirements-dev.txt"):
        se.get_scan_engine("jax")
    # the default engine never touches jax
    r = MPI.run_schedule_many(RecursiveDoublingAllreduce(), (4096,), 8,
                              engine="numpy")
    assert r.latency_us.shape == (1,)


# ------------------------------------------------- batched schedule runs
@pytest.mark.parametrize("sched_cls", [RecursiveDoublingAllreduce,
                                       RabenseifnerAllreduce])
def test_size_grid_batched_equals_per_size_loop(engine, sched_cls):
    """One batched replay over the OSU size grid == per-size interpreter
    runs, for both engines."""
    n = 16
    batch = MPI.run_schedule_many(sched_cls(), BYTES, n, engine=engine)
    for b, size in enumerate(BYTES):
        ref = MPI.run_schedule(sched_cls(), size, n, backend="interp")
        assert batch.latency_us[b] == pytest.approx(ref.latency_us,
                                                    rel=1e-9), size
        np.testing.assert_allclose(batch.clocks[b], ref.clocks,
                                   rtol=1e-9, atol=1e-12)


def test_arrival_offset_columns_match_interp(engine):
    """t0 turns the batch axis into a Monte-Carlo arrival-offset
    scenario axis: each column == an interpreted skewed fresh start."""
    n, size, B = 16, 4096, 5
    rng = np.random.default_rng(7)
    t0 = rng.uniform(0.0, 5.0, size=(n, B))
    sched = RecursiveDoublingAllreduce()
    batch = MPI.run_schedule_many(sched, (size,) * B, n, t0=t0,
                                  engine=engine)
    for b in range(B):
        ref = MPI.run_schedule(sched, size, n, backend="interp",
                               t0=list(t0[:, b]))
        assert batch.latency_us[b] == pytest.approx(ref.latency_us,
                                                    rel=1e-9), b
        np.testing.assert_allclose(batch.clocks[b], ref.clocks,
                                   rtol=1e-9, atol=1e-12)


def test_run_schedule_t0_exact_on_compiled_backend():
    """A skewed *fresh* start (t0 with reset=True) is now exact on the
    compiled backend too — only reset=False stays interpreter-only."""
    n, size = 8, 65536
    t0 = [0.0, 3.25, 1.5, 0.75, 2.0, 0.0, 4.125, 0.5]
    sched = RabenseifnerAllreduce()
    a = MPI.run_schedule(sched, size, n, backend="interp", t0=t0)
    b = MPI.run_schedule(sched, size, n, backend="compiled", t0=t0)
    assert b.latency_us == pytest.approx(a.latency_us, rel=1e-9)
    for x, y in zip(a.clocks, b.clocks):
        assert y == pytest.approx(x, rel=1e-9, abs=1e-12)
    with pytest.raises(ValueError, match="nonzero occupancy"):
        MPI.run_schedule(sched, size, n, backend="compiled", t0=t0,
                         reset=False)


# -------------------------------------------------- batched program runs
@pytest.mark.parametrize("seed", range(4))
def test_program_batch_equals_per_binding_loop(engine, seed):
    """run_program_many batches mixed-structure fuzz programs (tag
    permutations, eager/rdv payloads, compute skew, embedded
    collectives) through bind_batch; every column == its own
    interpreted run."""
    rng = random.Random(9000 + seed)
    progs = []
    for _ in range(2):  # two base structures -> exercises grouping
        base = _fuzz_program(rng, rng.choice([4, 8, 16]))
        comp, post, _ = extract_data(base)
        progs.append(base)
        for _ in range(2):  # payload variants share the base's artifact
            f = rng.choice([0.0, 0.5, 1.0, 7.3, 130.0])
            g = rng.uniform(0.25, 4.0)
            progs.append(rebind_program(
                base,
                compute_us=[c * g for c in comp],
                post_nbytes=[int(round(x * f)) for x in post]))
    rng.shuffle(progs)
    got = MPI.run_program_many(progs, backend="compiled", engine=engine)
    for i, p in enumerate(progs):
        ref = MPI.run_program(p, backend="interp")
        _assert_equal(ref, got[i], ("batch", seed, i))


def test_scenario_sweep_matches_rebound_interp(engine):
    """bind_arrays scenario columns (per-scenario compute skew + byte
    jitter) == rebind_program + interpreter, column by column.  Uses a
    wave-structured builder — scenario binding requires the scheduling
    order to be payload-invariant (the fuzz programs are not, and the
    check= guard rejects them; see test below)."""
    from repro.core.program import cg_iteration
    prog = cg_iteration(8, 70000, 30.0)
    comp, post, _ = extract_data(prog)
    base_comp = np.array(comp, dtype=np.float64)
    base_post = np.array(post, dtype=np.float64)
    N = 6
    nrng = np.random.default_rng(11)
    cs = nrng.uniform(0.5, 2.0, size=N)
    bs = nrng.uniform(0.25, 3.0, size=N)
    res = MPI.run_program_scenarios(prog, compute_scale=cs, byte_scale=bs,
                                    engine=engine)
    assert len(res) == N
    for b in range(N):
        pb = rebind_program(prog, compute_us=base_comp * cs[b],
                            post_nbytes=np.rint(base_post * bs[b]))
        ref = MPI.run_program(pb, backend="interp")
        _assert_equal(ref, res[b], ("scenario", b))


def test_scenario_per_rank_skew_passes_internal_check(engine):
    """(nranks, N) compute_scale routes per-rank skew through the
    artifact's compute->rank map; check=N cross-checks every column
    against the interpreter and raises on >1e-9 disagreement."""
    from repro.core.program import halo3d
    prog = halo3d(8, 4096, 40.0, overlap=True)
    N = 4
    nrng = np.random.default_rng(5)
    cs = nrng.uniform(0.5, 2.0, size=(8, N))
    res = MPI.run_program_scenarios(prog, compute_scale=cs, engine=engine,
                                    check=N)
    assert len(res) == N


def test_scenario_check_rejects_payload_dependent_order():
    """The check= guard catches builders whose heap firing order shifts
    with the payload (fuzz programs): it must raise, pointing at
    run_program_many, instead of silently returning wrong latencies."""
    from repro.core.exanet.exec_compiled import ProgramStructureError
    nrng = np.random.default_rng(11)
    for seed in range(20):
        prog = _fuzz_program(random.Random(4242 + seed), 8)
        try:
            MPI.run_program_scenarios(
                prog, compute_scale=nrng.uniform(0.5, 2.0, size=6),
                byte_scale=nrng.uniform(0.25, 3.0, size=6), check=6)
        except ProgramStructureError as e:
            assert "run_program_many" in str(e)
            return
    pytest.skip("no payload-dependent fuzz program in 20 seeds")


def test_scenario_argument_validation():
    prog = _fuzz_program(random.Random(1), 4)
    with pytest.raises(ValueError, match="at least one of compute_scale"):
        MPI.run_program_scenarios(prog)
    with pytest.raises(ValueError, match="disagrees on N"):
        MPI.run_program_scenarios(prog, compute_scale=np.ones(3),
                                  byte_scale=np.ones(4))
    with pytest.raises(ValueError, match=r"\(N,\), \(nranks, N\) or "
                                         r"\(n_computes, N\)"):
        MPI.run_program_scenarios(prog, compute_scale=np.ones((3, 2)))


# ------------------------------------------------------------- auto gate
def test_auto_rank_floor_keeps_small_programs_interpreted():
    """The BENCH_apps nranks=2 regression (speedup_compiled = 0.87x):
    below the rank floor backend="auto" must interpret — no compiled
    artifact is built, and results equal the interpreter exactly."""
    m = ExanetMPI()
    prog = _fuzz_program(random.Random(3), 2)
    assert not m._program_auto_compiles(prog, {})
    a = m.run_program(prog, backend="auto")
    assert prog.structure_key() not in getattr(m, "_app_program_cache", {})
    ref = m.run_program(prog, backend="interp")
    _assert_equal(ref, a, "auto-floor-single")
    outs = m.run_program_many([prog, prog], backend="auto")
    assert prog.structure_key() not in getattr(m, "_app_program_cache", {})
    for r in outs:
        _assert_equal(ref, r, "auto-floor-many")


def test_auto_compiles_above_floor(monkeypatch):
    """At/above the floor the consolidated gate compiles (tracing off,
    splices profitable) and agrees with the interpreter — the positive
    side of the regression, floor lowered so the test stays fast."""
    monkeypatch.setattr(ExanetMPI, "PROGRAM_COMPILED_AUTO_MIN_RANKS", 2)
    m = ExanetMPI()
    from repro.core.program import halo3d
    prog = halo3d(8, 4096, 12.5)
    assert m._program_auto_compiles(prog, {})
    a = m.run_program(prog, backend="auto")
    assert prog.structure_key() in m._app_program_cache
    ref = m.run_program(prog, backend="interp")
    _assert_equal(ref, a, "auto-above-floor")
