"""Serving simulator tests: the scenario-axis seams (per-rank t0,
site_scale, per-post byte_scale), the serve-step Program emitter, the
batched step table vs the per-step lane, and the open-loop traffic
replay's queueing arithmetic."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.exanet.mpi import ExanetMPI
from repro.core.exanet.params import DEFAULT
from repro.core.program import (Collective, Compute, Irecv, Isend, Program,
                                ProgramError, Wait)
from repro.serve import traffic
from repro.serve.sim import ServeSim, ServeSimSpec


@pytest.fixture(scope="module")
def mpi():
    return ExanetMPI(DEFAULT)


def serve_like_program(nranks=8, us=5.0, act=4096, kv=1024) -> Program:
    ops = (Compute(us=us),
           Collective(op="allgather", nbytes=act,
                      algo="recursive_doubling"),
           Collective(op="alltoall", nbytes=kv, algo="pairwise"))
    return Program(tuple(ops for _ in range(nranks)))


# --------------------------------------------------------------- t0 seam
def test_t0_interp_matches_compiled(mpi):
    prog = serve_like_program()
    t0 = np.random.default_rng(0).uniform(0.0, 3.0, 8)
    a = mpi.run_program(prog, backend="interp", t0=t0)
    b = mpi.run_program(prog, backend="compiled", t0=t0)
    assert abs(a.latency_us - b.latency_us) <= 1e-9 * abs(a.latency_us)
    for x, y in zip(a.clocks, b.clocks):
        assert abs(x - y) <= 1e-9 * max(abs(x), 1e-12)


def test_t0_scalar_shifts_everything(mpi):
    prog = serve_like_program()
    a = mpi.run_program(prog, backend="interp")
    b = mpi.run_program(prog, backend="interp", t0=7.5)
    assert b.latency_us == pytest.approx(a.latency_us + 7.5, rel=1e-12)


def test_t0_wrong_length_rejected(mpi):
    prog = serve_like_program()
    with pytest.raises((ValueError, ProgramError)):
        mpi.run_program(prog, backend="interp", t0=[1.0, 2.0])


def test_t0_on_p2p_program_agrees(mpi):
    # the seam is not collective-specific: a halo-style ring with waits
    ops = []
    for r in range(4):
        ops.append((Compute(us=2.0), Isend(dst=(r + 1) % 4, nbytes=512,
                                           tag=3),
                    Irecv(src=(r - 1) % 4, nbytes=512, tag=3), Wait()))
    prog = Program(tuple(ops))
    t0 = np.array([0.0, 0.4, 0.1, 0.3])
    a = mpi.run_program(prog, backend="interp", t0=t0)
    b = mpi.run_program(prog, backend="compiled", t0=t0)
    assert abs(a.latency_us - b.latency_us) <= 1e-9 * abs(a.latency_us)


# ------------------------------------------------- scenario-axis seams
def test_scenarios_site_scale_and_t0_checked(mpi):
    prog = serve_like_program()
    rng = np.random.default_rng(1)
    N = 10
    cs = rng.uniform(0.5, 2.0, (8, N))
    ss = rng.uniform(0.25, 3.0, (2, N))
    t0 = rng.uniform(0.0, 4.0, (8, N))
    # check=N: every column re-run on the interpreter, raises on >1e-9
    res = mpi.run_program_scenarios(prog, compute_scale=cs, site_scale=ss,
                                    t0=t0, check=N)
    assert len(res) == N
    assert all(r.latency_us > 0 for r in res)


def test_scenarios_t0_only_sweep(mpi):
    prog = serve_like_program()
    t0 = np.random.default_rng(2).uniform(0.0, 5.0, (8, 6))
    res = mpi.run_program_scenarios(prog, t0=t0, check=6)
    assert len(res) == 6
    # columns with larger skew must not finish earlier than the skew
    assert all(r.latency_us >= t0[:, i].max()
               for i, r in enumerate(res))


def test_scenarios_per_post_byte_scale(mpi):
    # per-post scaling must keep matched send/recv pairs consistent;
    # scale per ring channel and map it to both endpoints
    ops = []
    for r in range(4):
        ops.append((Compute(us=1.0), Isend(dst=(r + 1) % 4, nbytes=2048,
                                           tag=7),
                    Irecv(src=(r - 1) % 4, nbytes=2048, tag=7), Wait()))
    prog = Program(tuple(ops))
    rng = np.random.default_rng(3)
    chan = rng.uniform(0.3, 4.0, (4, 5))
    bs = np.empty((8, 5))
    for r in range(4):
        bs[2 * r] = chan[r]                  # rank r's Isend
        bs[2 * ((r + 1) % 4) + 1] = chan[r]  # peer's matching Irecv
    res = mpi.run_program_scenarios(prog, byte_scale=bs, check=5)
    assert len(res) == 5


def test_scenarios_inconsistent_per_post_scale_rejected(mpi):
    ops = []
    for r in range(4):
        ops.append((Isend(dst=(r + 1) % 4, nbytes=2048, tag=7),
                    Irecv(src=(r - 1) % 4, nbytes=2048, tag=7), Wait()))
    prog = Program(tuple(ops))
    bs = np.random.default_rng(4).uniform(0.3, 4.0, (8, 3))
    with pytest.raises(ProgramError):
        mpi.run_program_scenarios(prog, byte_scale=bs)


# ------------------------------------------------------------- the emitter
def small_spec(**kw) -> ServeSimSpec:
    base = dict(arch="exanest-lm-100m", nranks=8, slots=3, window=128,
                prefill_chunk=32, kv_buckets=2, arrival_skew_us=1.0)
    base.update(kw)
    return ServeSimSpec(**base)


def test_emitted_structure_is_state_invariant():
    sim = ServeSim(small_spec())
    key = None
    for (nd, npf, kvb) in sim.step_states():
        prog = sim.emit_step(nd, npf, float(sim.spec.kv_centers()[kvb]))
        k = prog.structure_key()
        assert key is None or k == key, \
            "serve steps must all bind to one artifact"
        key = k


def test_kv_exchange_op_switches_at_rank_cap():
    assert ServeSim(small_spec()).kv_exchange_op() == \
        ("alltoall", "pairwise")
    sim = ServeSim(small_spec(nranks=256, alltoall_max_ranks=128))
    assert sim.kv_exchange_op() == ("allgather", "recursive_doubling")


def test_step_cost_monotone_in_load_and_kv():
    sim = ServeSim(small_spec())
    base = sim.rank_compute_us(1, 0, 16.0)
    assert sim.rank_compute_us(3, 0, 16.0) > base      # more decodes
    assert sim.rank_compute_us(1, 1, 16.0) > base      # plus a prefill
    assert sim.rank_compute_us(1, 0, 100.0) > base     # longer context


def test_nonpow2_ranks_rejected():
    with pytest.raises(ValueError, match="power of two"):
        ServeSim(small_spec(nranks=6))


# ---------------------------------------------- table vs per-step lane
def test_table_matches_per_step_lane():
    sim = ServeSim(small_spec())
    tab = sim.build_table(mc=2, rng=0, check=4)
    assert tab.us.shape == (len(tab.states), 2)
    for state in tab.states[::3]:
        for j in range(tab.mc):
            batched = tab.us[tab.index[state], j]
            single = sim.step_time_single(tab, state, j,
                                          backend="interp")
            assert abs(batched - single) <= 1e-9 * abs(single), \
                f"lane disagreement at {state} draw {j}"


def test_table_lookup_rotates_draws():
    sim = ServeSim(small_spec())
    tab = sim.build_table(mc=2, rng=0)
    s = tab.states[0]
    assert tab.lookup(*s, step=0) == tab.us[tab.index[s], 0]
    assert tab.lookup(*s, step=3) == tab.us[tab.index[s], 1]


# ------------------------------------------------------------- roofline
def test_lm_serve_step_cost_sanity():
    from repro.configs import get
    from repro.roofline.analysis import lm_serve_step_cost
    cfg = get("exanest-lm-100m")
    c1 = lm_serve_step_cost(cfg, n_decode=1, decode_kv=64.0)
    # one decode token costs at least 2 flops per parameter
    assert c1["flops"] >= 2 * cfg.param_count()
    # a batch of 8 shares the weight sweep: less than 8x the bytes
    c8 = lm_serve_step_cost(cfg, n_decode=8, decode_kv=64.0)
    assert c8["flops"] > c1["flops"]
    assert c8["hbm_bytes"] < 8 * c1["hbm_bytes"]
    # idle step costs nothing
    c0 = lm_serve_step_cost(cfg, n_decode=0, decode_kv=0.0)
    assert c0["flops"] == 0.0 and c0["hbm_bytes"] == 0.0
    # prefill moves KV shards, decode does not
    cp = lm_serve_step_cost(cfg, n_decode=0, decode_kv=0.0, n_prefill=32)
    assert cp["kv_bytes"] > 0 and c1["kv_bytes"] == 0.0


# ------------------------------------------------------------- traffic
def test_replay_hand_computed_timeline():
    wl = traffic.trace_workload([0.0, 0.0, 0.0], [64, 64, 64], [3, 3, 3])
    res = traffic.replay(wl, slots=2, prefill_chunk=64, window=256,
                         kv_bucket=lambda kv: 0,
                         step_time=lambda nd, npf, kvb, i: 10.0)
    # slots 2: r0,r1 prefill (step 1), decode x2 (steps 2-3) -> done @30;
    # r2 admitted @30, prefill (step 4) -> first @40, done @60
    assert res.admit_us.tolist() == [0.0, 0.0, 30.0]
    assert res.first_us.tolist() == [10.0, 10.0, 40.0]
    assert res.done_us.tolist() == [30.0, 30.0, 60.0]
    assert res.n_steps == 6
    assert res.tokens_out == 9


def test_replay_idle_jumps_to_next_arrival():
    wl = traffic.trace_workload([1000.0], [32], [2])
    res = traffic.replay(wl, slots=2, prefill_chunk=32, window=64,
                         kv_bucket=lambda kv: 0,
                         step_time=lambda nd, npf, kvb, i: 5.0)
    assert res.admit_us[0] == 1000.0
    assert res.done_us[0] == 1010.0


def test_replay_window_truncates():
    wl = traffic.trace_workload([0.0], [8], [1000])
    res = traffic.replay(wl, slots=1, prefill_chunk=8, window=16,
                         kv_bucket=lambda kv: 0,
                         step_time=lambda nd, npf, kvb, i: 1.0)
    # prefill 8, then 8 decodes fill the window
    assert res.tokens_out == 9          # 1 from prefill + 8 decodes
    assert np.isfinite(res.done_us[0])


def test_open_loop_overload_diverges():
    wl_lo = traffic.poisson_workload(100.0, 60, 0, prompt_tokens=16,
                                     out_tokens=8)
    wl_hi = traffic.poisson_workload(10000.0, 60, 0, prompt_tokens=16,
                                     out_tokens=8)
    kw = dict(slots=2, prefill_chunk=16, window=64,
              kv_bucket=lambda kv: 0,
              step_time=lambda nd, npf, kvb, i: 100.0)
    lo = traffic.replay(wl_lo, **kw)
    hi = traffic.replay(wl_hi, **kw)
    assert np.quantile(hi.latency_us, 0.99) > \
        5 * np.quantile(lo.latency_us, 0.99)


def test_workload_validation():
    with pytest.raises(ValueError, match="sorted"):
        traffic.trace_workload([3.0, 1.0], [4, 4], [2, 2])
    with pytest.raises(ValueError, match=">= 1"):
        traffic.trace_workload([0.0], [0], [2])
    with pytest.raises(ValueError, match="length"):
        traffic.trace_workload([0.0], [4, 4], [2])


def test_quantiles_and_cdf():
    v = np.arange(1, 1001, dtype=float)
    q = traffic.quantiles(v)
    assert q["p50"] == pytest.approx(500.5)
    assert q["p999"] == pytest.approx(999.001)
    pts = traffic.cdf_points(v, 16)
    fr = [p[1] for p in pts]
    assert fr == sorted(fr) and fr[-1] == 1.0


def test_knee_point():
    assert traffic.knee_point([10, 20, 40], [10, 19.5, 25]) == 20.0
    assert traffic.knee_point([10, 20], [5, 6]) is None
