"""HLO cost-model coverage on real MoE/MLA configs (ISSUE-9 satellite).

``synth_train_hlo`` emits a parser-compatible training-step module —
nested whiles over the dense and MoE layer stacks inside a microbatch
loop, per-layer attention/MLP/expert dots, an LM-head dot and a
gradient all-reduce — and this file pins that ``analyze_hlo`` rolls it
up correctly: per-layer flop/byte sanity bounds, ``_trip_multipliers``
on the nested loops, and agreement with the closed-form
``lm_train_step_cost`` anchor.
"""

import pytest

from repro.configs import get
from repro.roofline.analysis import lm_train_step_cost
from repro.roofline.hlo_cost import (HloCostModel, _trip_multipliers,
                                     analyze_hlo, synth_train_hlo)

SEQ = 512


def _analyzed(arch, *, microbatches=1):
    cfg = get(arch)
    hlo = synth_train_hlo(cfg, seq_len=SEQ, microbatches=microbatches)
    return cfg, hlo, analyze_hlo(hlo)


# ---------------------------------------------------- trip multipliers
@pytest.mark.parametrize("arch,mb", [("deepseek-v3-671b", 2),
                                     ("mistral-large-123b", 3)])
def test_nested_trip_multipliers(arch, mb):
    cfg, hlo, _ = _analyzed(arch, microbatches=mb)
    mult = _trip_multipliers(HloCostModel(hlo))
    assert mult["%mb_body"] == mb
    if getattr(cfg, "moe", None):
        n_dense = getattr(cfg, "n_dense_layers", 0) or 0
        # nested whiles multiply: stack trips x microbatch trips
        assert mult["%dense_body"] == n_dense * mb
        assert mult["%moe_body"] == (cfg.n_layers - n_dense) * mb
    else:
        assert mult["%dense_body"] == cfg.n_layers * mb
        assert "%moe_body" not in mult
    # nested computation bodies never count as entry roots
    assert all(v >= 1 for v in mult.values())


def test_microbatch_near_invariance_of_totals():
    """Splitting the batch over microbatches keeps the matmul flops
    identical (same tokens, more loop iterations); only the attention
    quadratic term shrinks (each microbatch attends within its own
    seq/mb chunk), so totals drop slightly but never grow."""
    _, _, one = _analyzed("mistral-large-123b", microbatches=1)
    _, _, four = _analyzed("mistral-large-123b", microbatches=4)
    assert four["flops"] <= one["flops"]
    assert four["flops"] == pytest.approx(one["flops"], rel=0.02)


# ------------------------------------------- closed-form cross anchors
@pytest.mark.parametrize("arch,lo,hi", [("deepseek-v3-671b", 0.7, 1.3),
                                        ("mistral-large-123b", 0.7, 1.3),
                                        ("exanest-lm-100m", 0.6, 1.2)])
def test_hlo_flops_track_closed_form(arch, lo, hi):
    cfg, _, rep = _analyzed(arch)
    closed = lm_train_step_cost(cfg, seq_len=SEQ, batch=1)
    ratio = rep["flops"] / closed["fwd_flops"]
    assert lo < ratio < hi, ratio


def test_allreduce_bytes_are_fp32_gradient():
    for arch in ("deepseek-v3-671b", "exanest-lm-100m"):
        cfg, _, rep = _analyzed(arch)
        coll = rep["collectives"]
        assert coll["all-reduce"] == cfg.param_count() * 4
        assert coll["ops"]["all-reduce"] == 1
        assert coll["total"] == coll["all-reduce"]


# -------------------------------------------- per-layer sanity bounds
def test_moe_layer_flops_scale_with_active_params():
    """A sparse MoE step must cost like its *active* parameter count,
    nowhere near its total parameter count."""
    cfg, _, rep = _analyzed("deepseek-v3-671b")
    tokens = SEQ
    dense_equiv = 2.0 * tokens * cfg.param_count()
    active_equiv = 2.0 * tokens * cfg.active_param_count()
    assert rep["flops"] < 0.5 * dense_equiv
    assert rep["flops"] > 0.5 * active_equiv


def test_dense_layer_flops_per_token_bounds():
    """Dense model: per-token flops within [2P, 4P] — matmul lower
    bound plus attention's quadratic term at modest sequence length."""
    cfg, _, rep = _analyzed("mistral-large-123b")
    per_tok = rep["flops"] / SEQ
    p = cfg.param_count()
    assert 2.0 * p * 0.9 < per_tok < 4.0 * p


def test_bytes_are_positive_and_dominated_by_weights():
    for arch in ("deepseek-v3-671b", "mistral-large-123b",
                 "exanest-lm-100m"):
        cfg, _, rep = _analyzed(arch)
        assert rep["bytes"] > 0
        # at seq 512 the weight traffic should dominate activations
        assert rep["bytes"] > cfg.param_count()  # >= 1 byte/param touched


def test_kv_projection_width_in_emitted_hlo():
    """The kv dot's N dimension is 2*n_kv_heads*head_dim — and for a
    GQA config that is strictly narrower than the q projection."""
    for arch in ("deepseek-v3-671b", "exanest-lm-100m"):
        cfg, hlo, _ = _analyzed(arch)
        hd = cfg.resolved_head_dim
        assert f"{2 * cfg.n_kv_heads * hd}]" in hlo
    gqa = get("exanest-lm-100m")
    assert gqa.n_kv_heads < gqa.n_heads
